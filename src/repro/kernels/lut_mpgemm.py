"""LUT-based mixed-precision GEMM Pallas TPU kernel (paper Fig. 1a right).

Computes Y = W~ @ X where W~[i, j] = T[i, Q[i, j]] without ever
materializing W~ in HBM: packed 4-bit codes stream HBM->VMEM at
0.5 bytes/weight and are decoded tile-by-tile inside the matmul.

TPU adaptation of the GPU shared-memory LUT (SqueezeLLM kernels): TPUs have
no efficient per-lane gather, so the per-row table lookup is re-expressed as
a 2^N-way compare-select accumulation on the VPU — for each codebook slot s,
`acc += T[:, s] * (codes == s)` — which vectorizes perfectly and feeds the
decoded tile straight into the MXU. The codebook tile (block_m x 2^N fp32,
e.g. 128x16 = 8 KiB) plays the role of the GPU shared-memory LUT and stays
VMEM-resident for the whole K loop.

Packed layout trick: rather than interleaving nibbles inside the kernel
(an awkward lane shuffle on TPU), the wrapper pre-splits X by row parity and
the kernel computes  Y = W_lo @ X_even + W_hi @ X_odd  — two clean MXU calls
per tile, zero shuffles.

Grid: (m_blocks, p_blocks, k_blocks), K innermost/sequential with an f32
VMEM accumulator (flash-style).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decode_tile(codes: jnp.ndarray, t: jnp.ndarray, levels: int) -> jnp.ndarray:
    """(bm, bk) uint8 codes + (bm, L) table -> (bm, bk) f32 via compare-select."""
    acc = jnp.zeros(codes.shape, jnp.float32)
    for s in range(levels):
        acc += t[:, s][:, None] * (codes == s).astype(jnp.float32)
    return acc


def _lut_kernel_unpacked(codes_ref, t_ref, x_ref, o_ref, acc_ref, *,
                         levels: int, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _decode_tile(codes_ref[...], t_ref[...].astype(jnp.float32), levels)
    acc_ref[...] += jnp.dot(w, x_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _lut_kernel_packed(packed_ref, t_ref, xe_ref, xo_ref, o_ref, acc_ref, *,
                       levels: int, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    packed = packed_ref[...]
    t = t_ref[...].astype(jnp.float32)
    w_lo = _decode_tile(packed & 0xF, t, levels)
    w_hi = _decode_tile(packed >> 4, t, levels)
    acc_ref[...] += jnp.dot(w_lo, xe_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)
    acc_ref[...] += jnp.dot(w_hi, xo_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(a, axis, mult, value=0):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=(
    "bits", "block_m", "block_k", "block_p", "interpret"))
def lut_matmul(codes: jnp.ndarray, codebook: jnp.ndarray, x: jnp.ndarray, *,
               bits: int = 4, block_m: int = 128, block_k: int = 512,
               block_p: int = 128, interpret: bool = False) -> jnp.ndarray:
    """Y = decode(codes, codebook) @ x with unpacked uint8 codes.

    codes: (m, n) uint8 < 2**bits; codebook: (m, 2**bits); x: (n, p).
    Returns (m, p) in x.dtype.
    """
    m, n = codes.shape
    p = x.shape[1]
    levels = 1 << bits
    bm, bk, bp = min(block_m, m), min(block_k, n), min(block_p, p)

    cp = _pad_to(_pad_to(codes, 0, bm), 1, bk)
    tp = _pad_to(codebook, 0, bm)
    xp = _pad_to(_pad_to(x, 0, bk), 1, bp)
    mp, np_ = cp.shape
    pp = xp.shape[1]
    nm, nk, npb = mp // bm, np_ // bk, pp // bp

    out = pl.pallas_call(
        functools.partial(_lut_kernel_unpacked, levels=levels, nk=nk),
        grid=(nm, npb, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, levels), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bk, bp), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bp), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, pp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bp), jnp.float32)],
        interpret=interpret,
    )(cp, tp, xp)
    return out[:m, :p]


@functools.partial(jax.jit, static_argnames=(
    "bits", "block_m", "block_k", "block_p", "interpret"))
def lut_matmul_packed(packed: jnp.ndarray, codebook: jnp.ndarray,
                      x: jnp.ndarray, *, bits: int = 4, block_m: int = 128,
                      block_k: int = 512, block_p: int = 128,
                      interpret: bool = False) -> jnp.ndarray:
    """Y = decode(packed nibbles) @ x; packed: (m, ceil(n/2)) uint8.

    X is split by row parity outside the kernel so decode needs no
    interleave: Y = W_lo @ X_even + W_hi @ X_odd.
    """
    m, half = packed.shape
    assert x.shape[0] in (2 * half, 2 * half - 1), \
        (f"x rows ({x.shape[0]}) must match the packed K axis "
         f"(2*{half} nibbles, odd n allowed one short)")
    p = x.shape[1]
    levels = 1 << bits
    # split X rows by parity (pad odd n with a zero row first)
    xq = _pad_to(x, 0, 2)
    x_even, x_odd = xq[0::2], xq[1::2]

    bm = min(block_m, m)
    bkh = min(block_k // 2, half)          # block over the *packed* axis
    bp = min(block_p, p)

    pp_ = _pad_to(_pad_to(packed, 0, bm), 1, bkh)
    tp = _pad_to(codebook, 0, bm)
    xe = _pad_to(_pad_to(x_even, 0, bkh), 1, bp)
    xo = _pad_to(_pad_to(x_odd, 0, bkh), 1, bp)
    mp, halfp = pp_.shape
    ppad = xe.shape[1]
    nm, nk, npb = mp // bm, halfp // bkh, ppad // bp

    out = pl.pallas_call(
        functools.partial(_lut_kernel_packed, levels=levels, nk=nk),
        grid=(nm, npb, nk),
        in_specs=[
            pl.BlockSpec((bm, bkh), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, levels), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bkh, bp), lambda i, j, k: (k, j)),
            pl.BlockSpec((bkh, bp), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bp), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, ppad), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bp), jnp.float32)],
        interpret=interpret,
    )(pp_, tp, xe, xo)
    return out[:m, :p]
