"""LUT-based mixed-precision GEMM Pallas TPU kernels (paper Fig. 1a right).

Computes Y = W~ @ X where W~[i, j] = T[i, Q[i, j]] without ever
materializing W~ in HBM: quantized codes stream HBM->VMEM at their true
container width (bits/8 bytes per weight for the bitstream layout) and are
decoded tile-by-tile inside the matmul.

TPU adaptation of the GPU shared-memory LUT (SqueezeLLM kernels): TPUs have
no efficient per-lane gather, so the per-row table lookup is re-expressed as
a 2^N-way compare-select on the VPU — the accumulator is initialized to
T[:, 0] and each remaining slot s selects `where(codes == s, T[:, s], acc)`
— which vectorizes perfectly and feeds the decoded tile straight into the
MXU. The codebook tile (block_m x 2^N fp32, e.g. 128x16 = 8 KiB) plays the
role of the GPU shared-memory LUT and stays VMEM-resident for the whole K
loop.

Packed layout trick, generalized: rather than interleaving sub-byte codes
inside the kernel (an awkward lane shuffle on TPU), the wrapper pre-splits
X by *residue class* of the code index. For a container stream width of
`sb` bits per code the layout repeats every g = sb/gcd(sb,8) bytes holding
ph = 8/gcd(sb,8) codes, so the wrapper passes g byte-plane tiles and ph
X-phase tiles; decode is then static shifts + one compare-select pass over
the phase-concatenated codes, and a single MXU call contracts the
phase-stacked tiles:

    Y = [W_0 | W_1 | ... | W_{ph-1}] @ [X_0; X_1; ...; X_{ph-1}]

For sb=4 (nibble container) this degenerates to the classic parity split
(g=1, ph=2); sb=3 gives the true 3/8-byte bitstream (g=3, ph=8) with zero
wasted HBM bandwidth; sb=8 is the unpacked layout (g=1, ph=1).

`lut_matmul_grouped` extends the same kernel over an output-group axis:
G projections sharing the input stream (Q/K/V, gate/up) ride one launch
with stacked codes/codebooks, so each X tile is fetched HBM->VMEM once
and feeds G decoded dots instead of being re-streamed per projection.

Grid: (m_blocks, p_blocks, k_blocks), K innermost/sequential with an f32
VMEM accumulator (flash-style).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def phase_split(stream_bits: int):
    """(bytes per group g, codes per group ph) for a container stream
    width: the layout repeats every lcm(stream_bits, 8) bits."""
    d = math.gcd(stream_bits, 8)
    return stream_bits // d, 8 // d


def _decode_tile(codes: jnp.ndarray, t: jnp.ndarray, levels: int) -> jnp.ndarray:
    """(bm, bk) int codes + (bm, L) f32 table -> (bm, bk) f32.

    Compare-select decode with slot 0 as the accumulator init: levels-1
    selects, no multiply-accumulate (code 0 costs nothing). Equality masks
    for a tile are computed exactly once — callers that feed several MXU
    operands from one tile (packed lo/hi halves, bitstream phases) decode
    the phase-concatenated codes in a single pass.
    """
    acc = jnp.broadcast_to(t[:, 0][:, None], codes.shape)
    for s in range(1, levels):
        acc = jnp.where(codes == s, t[:, s][:, None], acc)
    return acc


def _extract_phase_codes(planes: jnp.ndarray, stream_bits: int) -> jnp.ndarray:
    """(g, bm, bkg) uint8 byte planes -> (bm, ph*bkg) codes.

    Static shifts only (phase q of a group lives at bit offset q*sb, the
    same place in every group), so decode needs no lane shuffles; codes
    spanning a byte boundary merge two planes.
    """
    g, ph = phase_split(stream_bits)
    mask = (1 << stream_bits) - 1
    p32 = [planes[i].astype(jnp.int32) for i in range(g)]
    phases = []
    for q in range(ph):
        off = q * stream_bits
        lo, sh = off // 8, off % 8
        c = p32[lo] >> sh
        if sh + stream_bits > 8:                # code spans two bytes
            c = c | (p32[lo + 1] << (8 - sh))
        phases.append(c & mask)
    return jnp.concatenate(phases, axis=-1)


def _lut_kernel_unpacked(codes_ref, t_ref, x_ref, o_ref, acc_ref, *,
                         levels: int, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _decode_tile(codes_ref[...].astype(jnp.int32),
                     t_ref[...].astype(jnp.float32), levels)
    acc_ref[...] += jnp.dot(w, x_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _lut_kernel_stream(codes_ref, t_ref, x_ref, o_ref, acc_ref, *,
                       stream_bits: int, levels: int, groups: int, nk: int):
    """Bit-parametric bitstream kernel, optionally over G output groups.

    codes_ref (G*g, bm, bkg) byte planes; t_ref (G, bm, L); x_ref
    (ph, bkg, bp) phase-split activations — fetched once per grid step and
    shared by all G groups' dots.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g, ph = phase_split(stream_bits)
    planes = codes_ref[...]
    bkg = planes.shape[-1]
    xs = x_ref[...]
    # phase-major row stack matches the phase-concatenated decode below
    x2 = xs.reshape(ph * bkg, xs.shape[-1]).astype(jnp.float32)
    for gi in range(groups):
        codes = _extract_phase_codes(planes[gi * g:(gi + 1) * g],
                                     stream_bits)
        w = _decode_tile(codes, t_ref[gi].astype(jnp.float32), levels)
        acc_ref[gi] += jnp.dot(w, x2, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(a, axis, mult, value=0):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=(
    "bits", "block_m", "block_k", "block_p", "interpret"))
def lut_matmul(codes: jnp.ndarray, codebook: jnp.ndarray, x: jnp.ndarray, *,
               bits: int = 4, block_m: int = 128, block_k: int = 512,
               block_p: int = 128, interpret: bool = False) -> jnp.ndarray:
    """Y = decode(codes, codebook) @ x with unpacked uint8 codes.

    codes: (m, n) uint8 < 2**bits; codebook: (m, 2**bits); x: (n, p).
    Returns (m, p) in x.dtype.
    """
    m, n = codes.shape
    p = x.shape[1]
    levels = 1 << bits
    bm, bk, bp = min(block_m, m), min(block_k, n), min(block_p, p)

    cp = _pad_to(_pad_to(codes, 0, bm), 1, bk)
    tp = _pad_to(codebook, 0, bm)
    xp = _pad_to(_pad_to(x, 0, bk), 1, bp)
    mp, np_ = cp.shape
    pp = xp.shape[1]
    nm, nk, npb = mp // bm, np_ // bk, pp // bp

    out = pl.pallas_call(
        functools.partial(_lut_kernel_unpacked, levels=levels, nk=nk),
        grid=(nm, npb, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, levels), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bk, bp), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bp), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, pp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bp), jnp.float32)],
        interpret=interpret,
    )(cp, tp, xp)
    return out[:m, :p]


def lut_matmul_packed(packed: jnp.ndarray, codebook: jnp.ndarray,
                      x: jnp.ndarray, *, bits: int = 4, block_m: int = 128,
                      block_k: int = 512, block_p: int = 128,
                      interpret: bool = False) -> jnp.ndarray:
    """Y = decode(packed nibbles) @ x; packed: (m, ceil(n/2)) uint8.

    The nibble container IS the sb=4 bitstream (low nibble = even code),
    so this is the g=1/ph=2 degenerate case of the generic stream kernel:
    Y = [W_lo | W_hi] @ [X_even; X_odd] — one decode pass, one MXU call
    per tile, one implementation.
    """
    m, half = packed.shape
    assert x.shape[0] in (2 * half, 2 * half - 1), \
        (f"x rows ({x.shape[0]}) must match the packed K axis "
         f"(2*{half} nibbles, odd n allowed one short)")
    return lut_matmul_bitstream(packed, codebook, x, bits=bits,
                                stream_bits=4, block_m=block_m,
                                block_k=block_k, block_p=block_p,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "stream_bits", "levels", "block_m", "block_k", "block_p", "interpret"))
def _stream_matmul(codes: jnp.ndarray, books: jnp.ndarray, x: jnp.ndarray, *,
                   stream_bits: int, levels: int, block_m: int,
                   block_k: int, block_p: int,
                   interpret: bool) -> jnp.ndarray:
    """Grouped bitstream matmul core: codes (G, mu, ceil(n*sb/8)) uint8,
    books (G, mu, levels), x (n, p) -> (G, mu, p) in x.dtype."""
    gg, mu, cb = codes.shape
    n, p = x.shape
    g, ph = phase_split(stream_bits)
    assert cb == (n * stream_bits + 7) // 8, (cb, n, stream_bits)
    n_groups = -(-n // ph)

    # byte planes: group bytes are consecutive in the stream; plane b holds
    # byte b of every group -> (G*g, mu, n_groups)
    pad_bytes = n_groups * g - cb
    if pad_bytes:
        codes = jnp.pad(codes, ((0, 0), (0, 0), (0, pad_bytes)))
    planes = codes.reshape(gg, mu, n_groups, g).transpose(0, 3, 1, 2) \
        .reshape(gg * g, mu, n_groups)

    # X phases: row j = group*ph + q  ->  x_ph[q, group]
    xq = _pad_to(x, 0, ph * n_groups)
    x_ph = xq.reshape(n_groups, ph, p).transpose(1, 0, 2)

    bm = min(block_m, mu)
    bkg = max(1, min(block_k // ph, n_groups))
    bp = min(block_p, p)

    planes = _pad_to(_pad_to(planes, 1, bm), 2, bkg)
    books = _pad_to(books, 1, bm)
    x_ph = _pad_to(_pad_to(x_ph, 1, bkg), 2, bp)
    mup, ngp = planes.shape[1], planes.shape[2]
    pp = x_ph.shape[2]
    nm, nk, npb = mup // bm, ngp // bkg, pp // bp

    out = pl.pallas_call(
        functools.partial(_lut_kernel_stream, stream_bits=stream_bits,
                          levels=levels, groups=gg, nk=nk),
        grid=(nm, npb, nk),
        in_specs=[
            pl.BlockSpec((gg * g, bm, bkg), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((gg, bm, levels), lambda i, j, k: (0, i, 0)),
            pl.BlockSpec((ph, bkg, bp), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((gg, bm, bp), lambda i, j, k: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((gg, mup, pp), x.dtype),
        scratch_shapes=[pltpu.VMEM((gg, bm, bp), jnp.float32)],
        interpret=interpret,
    )(planes, books, x_ph)
    return out[:, :mu, :p]


def lut_matmul_bitstream(packed: jnp.ndarray, codebook: jnp.ndarray,
                         x: jnp.ndarray, *, bits: int,
                         stream_bits: int = None,
                         block_m: int = 128, block_k: int = 512,
                         block_p: int = 128,
                         interpret: bool = False) -> jnp.ndarray:
    """Y = decode(bitstream codes) @ x; packed: (m, ceil(n*sb/8)) uint8
    true bitstream (`core.packing.pack_bits` layout), where sb =
    `stream_bits` (container width; defaults to `bits`, but codes narrower
    than their container — e.g. 2-bit values in a 3-bit stream — pass
    both). Streams exactly sb/8 bytes per weight — for 3-bit, 33% less
    HBM than the nibble container."""
    sb = stream_bits if stream_bits is not None else bits
    y = _stream_matmul(packed[None], codebook[None], x, stream_bits=sb,
                       levels=1 << bits, block_m=block_m, block_k=block_k,
                       block_p=block_p, interpret=interpret)
    return y[0]


def _lut_kernel_nested(codes_ref, t_ref, x_ref, o_ref, acc_ref, *,
                       bits: int, draft_bits: int, nk: int):
    """Dual sub-stream kernel for the nested layout: codes_ref holds the
    prefix stream's g_hi byte planes then the remainder stream's g_lo
    planes ((g_hi + g_lo, bm, bkg)); both streams share one phase count
    (every 4-bit split has ph_hi == ph_lo), so the recombined full-width
    codes decode in a single compare-select pass and feed one MXU call —
    same shape discipline as `_lut_kernel_stream`, two plane sets."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rb = bits - draft_bits
    g_hi, _ = phase_split(draft_bits)
    planes = codes_ref[...]
    bkg = planes.shape[-1]
    hi = _extract_phase_codes(planes[:g_hi], draft_bits)
    lo = _extract_phase_codes(planes[g_hi:], rb)
    codes = (hi << rb) | lo
    w = _decode_tile(codes, t_ref[...].astype(jnp.float32), 1 << bits)
    xs = x_ref[...]
    x2 = xs.reshape(xs.shape[0] * bkg, xs.shape[-1]).astype(jnp.float32)
    acc_ref[...] += jnp.dot(w, x2, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "bits", "draft_bits", "block_m", "block_k", "block_p", "interpret"))
def lut_matmul_nested(packed: jnp.ndarray, codebook: jnp.ndarray,
                      x: jnp.ndarray, *, bits: int, draft_bits: int,
                      block_m: int = 128, block_k: int = 512,
                      block_p: int = 128,
                      interpret: bool = False) -> jnp.ndarray:
    """Full-width Y = decode(nested codes) @ x.

    packed: (m, hi_cols + lo_cols) uint8 — `core.packing.pack_bits_nested`
    layout (draft prefix stream, then remainder stream); codebook
    (m, 2**bits) sorted ascending per row; x (n, p). The DRAFT pass never
    lands here: it slices the prefix and rides `lut_matmul_bitstream` at
    stream width draft_bits (`kernels.ops.lut_linear`).
    """
    m, cb = packed.shape
    n, p = x.shape
    rb = bits - draft_bits
    g_hi, ph = phase_split(draft_bits)
    g_lo, ph_lo = phase_split(rb)
    assert ph == ph_lo, (draft_bits, rb, "sub-streams must share a phase "
                         "count — all 4-bit splits do")
    hi_cols = (n * draft_bits + 7) // 8
    lo_cols = (n * rb + 7) // 8
    assert cb == hi_cols + lo_cols, (cb, hi_cols, lo_cols)
    n_groups = -(-n // ph)

    def to_planes(stream, g):
        pad = n_groups * g - stream.shape[1]
        if pad:
            stream = jnp.pad(stream, ((0, 0), (0, pad)))
        return stream.reshape(m, n_groups, g).transpose(2, 0, 1)

    planes = jnp.concatenate(
        [to_planes(packed[:, :hi_cols], g_hi),
         to_planes(packed[:, hi_cols:], g_lo)], axis=0)  # (g_hi+g_lo, m, ng)

    xq = _pad_to(x, 0, ph * n_groups)
    x_ph = xq.reshape(n_groups, ph, p).transpose(1, 0, 2)

    bm = min(block_m, m)
    bkg = max(1, min(block_k // ph, n_groups))
    bp = min(block_p, p)

    planes = _pad_to(_pad_to(planes, 1, bm), 2, bkg)
    books = _pad_to(codebook, 0, bm)
    x_ph = _pad_to(_pad_to(x_ph, 1, bkg), 2, bp)
    g_all = planes.shape[0]
    mp, ngp = planes.shape[1], planes.shape[2]
    pp = x_ph.shape[2]
    nm, nk, npb = mp // bm, ngp // bkg, pp // bp

    out = pl.pallas_call(
        functools.partial(_lut_kernel_nested, bits=bits,
                          draft_bits=draft_bits, nk=nk),
        grid=(nm, npb, nk),
        in_specs=[
            pl.BlockSpec((g_all, bm, bkg), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((bm, 1 << bits), lambda i, j, k: (i, 0)),
            pl.BlockSpec((ph, bkg, bp), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bp), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, pp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bp), jnp.float32)],
        interpret=interpret,
    )(planes, books, x_ph)
    return out[:m, :p]


def lut_matmul_grouped(codes: jnp.ndarray, books: jnp.ndarray,
                       x: jnp.ndarray, *, bits: int, stream_bits: int = None,
                       block_m: int = 128, block_k: int = 512,
                       block_p: int = 128,
                       interpret: bool = False) -> jnp.ndarray:
    """Fused multi-projection LUT matmul: G output groups sharing one X.

    codes: (G, mu, cb) uint8 in the `stream_bits` container layout
    (8 = unpacked, 4 = nibble, otherwise true bitstream); books
    (G, mu, 2**bits); x (n, p). Returns (G, mu, p). One kernel launch
    streams X HBM->VMEM once per tile for all G groups instead of G times
    across separate launches.
    """
    sb = stream_bits if stream_bits is not None else bits
    return _stream_matmul(codes, books, x, stream_bits=sb,
                          levels=1 << bits, block_m=block_m,
                          block_k=block_k, block_p=block_p,
                          interpret=interpret)
