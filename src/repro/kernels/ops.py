"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode; on TPU they compile
natively. `lut_linear` is the serving entry point used by
models/quantized.py: it picks packed/unpacked layout and falls back to the
pure-XLA reference when Pallas is disabled (e.g. inside the 512-device
SPMD dry-run, where the jnp path keeps the HLO analyzable).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .backsub import backsub
from .lut_mpgemm import lut_matmul, lut_matmul_packed


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def lut_linear(codes_or_packed: jnp.ndarray, codebook: jnp.ndarray,
               x: jnp.ndarray, *, bits: int = 4, packed: bool = False,
               use_pallas: bool = True,
               fmt: Optional[str] = None) -> jnp.ndarray:
    """Y = W~ @ X for a LUT-quantized layer.

    Args:
      codes_or_packed: (m, n) uint8 codes, or (m, ceil(n/2)) nibble-packed.
      codebook: (m, 2**bits).
      x: (n, p) activations.
      fmt: optional `WeightFormat` name — when given, the code layout
        (packed or not) is read from the registry instead of the `packed`
        flag, so callers can route by format tag alone.
    """
    if fmt is not None:
        from repro.core.formats import get_format
        packed = get_format(fmt).packed
    if not use_pallas:
        if packed:
            return ref.lut_matmul_packed_ref(codes_or_packed, codebook, x)
        return ref.lut_matmul_ref(codes_or_packed, codebook, x)
    interpret = not _on_tpu()
    if packed:
        return lut_matmul_packed(codes_or_packed, codebook, x, bits=bits,
                                 interpret=interpret)
    return lut_matmul(codes_or_packed, codebook, x, bits=bits,
                      interpret=interpret)


def s_step_blocked(w: jnp.ndarray, t: jnp.ndarray, l: jnp.ndarray, *,
                   block_m: int = 128, block_n: int = 128,
                   use_pallas: bool = True):
    """GANQ S-step: Pallas blocked kernel (TPU) or scan oracle fallback."""
    if not use_pallas:
        return ref.backsub_ref(w, t, l)
    codes, wq = backsub(w, t, l, block_m=block_m, block_n=block_n,
                        interpret=not _on_tpu())
    return codes, wq


def vmem_plan(m: int, n: int, p: int, bits: int, block_m: int = 128,
              block_k: int = 512, block_p: int = 128) -> dict:
    """Static VMEM-footprint accounting for the LUT-mpGEMM kernel — used by
    the roofline analysis (HBM bytes = what the kernel actually streams).

    Per grid step resident set: packed codes tile, codebook tile, two X
    parity tiles, f32 accumulator. HBM traffic: packed codes read once
    (0.5 B/wt), X read m/block_m times, Y written once, LUT once.
    """
    levels = 1 << bits
    vmem = (block_m * block_k // 2            # packed codes tile (u8)
            + block_m * levels * 4            # codebook tile (f32)
            + block_k * block_p * 2           # X tiles (bf16, both parities)
            + block_m * block_p * 4)          # accumulator
    n_row_blocks = -(-m // block_m)
    hbm = {
        "codes_bytes": m * n * 0.5,
        "lut_bytes": m * levels * 2,
        "x_bytes": n * p * 2 * n_row_blocks,   # X re-streamed per row block
        "y_bytes": m * p * 2,
    }
    hbm["total_bytes"] = sum(hbm.values())
    return {"vmem_bytes": vmem, **hbm}
