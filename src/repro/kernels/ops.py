"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode; on TPU they compile
natively. `lut_linear` is the serving entry point used by
models/quantized.py: it routes on the container layout (unpacked / nibble /
true bitstream, read from the `WeightFormat` registry), picks tuned tile
sizes from `kernels.tune` when the shape has been autotuned, and falls
back to the pure-XLA reference when Pallas is disabled (e.g. inside the
512-device SPMD dry-run, where the jnp path keeps the HLO analyzable).
`lut_linear_grouped` fuses several projections sharing one activation
stream (Q/K/V, gate/up) into a single kernel launch.
"""
from __future__ import annotations

import functools
import math
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .backsub import backsub
from .lut_mpgemm import (lut_matmul, lut_matmul_bitstream,
                         lut_matmul_grouped, lut_matmul_nested,
                         lut_matmul_packed, phase_split)

# smallest worthwhile per-group row count for the fused projection kernel;
# below this the grouped tiles degenerate and sequential launches win
MIN_GROUP_ROWS = 8
# largest stacked group count: the kernel keeps every group's code tile
# and f32 accumulator VMEM-resident per grid step and unrolls a Python
# loop over groups, so extreme row ratios (MQA wq vs a single kv head)
# must fall back to sequential launches instead of blowing VMEM/compile
MAX_GROUPS = 16


def _group_unit(layers: Sequence) -> Tuple[int, int]:
    """(row unit mu = gcd of output widths, total group count G) for a
    fused launch — the single source of truth for group admissibility
    (groupable_layers) and code stacking (lut_linear_grouped)."""
    mu = 0
    for l in layers:
        mu = math.gcd(mu, l.shape[0])
    groups = sum(l.shape[0] // mu for l in layers) if mu else 0
    return mu, groups


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _layout(bits: int, packed: bool, fmt: Optional[str]) -> int:
    """Container stream width in bits per code: 8 unpacked, 4 nibble,
    otherwise the true bitstream width from the format registry."""
    if fmt is not None:
        from repro.core.formats import get_format
        sb = get_format(fmt).stream_bits
        assert sb is not None, f"format {fmt!r} has no LUT code stream"
        return sb
    return 4 if packed else 8


def _tuned_blocks(m: int, n: int, p: int, bits: int, fmt: Optional[str],
                  blocks, groups: int = 1, draft_bits: int = 0):
    if blocks is not None:
        return blocks.as_kwargs()
    if fmt is not None:
        from . import tune
        # groups is part of the key: a plan whose VMEM feasibility was
        # validated for a single launch must never be applied to a fused
        # launch whose tiles scale by the group count. draft_bits keys the
        # nested prefix read separately from the full-width read — the two
        # passes stream different byte counts per tile.
        plan = tune.lookup(m, n, p, bits, fmt, groups=groups,
                           draft_bits=draft_bits)
        if plan is not None:
            return plan.as_kwargs()
    return {}                     # kernel defaults (128/512/128)


def _nested_linear(packed: jnp.ndarray, codebook: jnp.ndarray,
                   x: jnp.ndarray, *, bits: int, fmt: str,
                   draft_bits: int, use_pallas: bool,
                   blocks) -> jnp.ndarray:
    """Nested dual-sub-stream route of `lut_linear`: full-width read
    recombines both streams (`lut_matmul_nested`); the draft read slices
    the contiguous prefix and rides the plain bitstream kernel at stream
    width draft_bits with the in-graph coarse codebook — ceil(n*db/8)
    code bytes, no second weight buffer."""
    from repro.core.codebook import nested_codebooks
    from repro.core.formats import get_format
    from repro.core.packing import code_stream_bytes
    f = get_format(fmt)
    db = f.draft_bits
    assert draft_bits in (0, db), (draft_bits, db, fmt)
    n, p = x.shape
    m = packed.shape[0]
    if draft_bits:
        prefix = packed[:, :code_stream_bytes(n, db)]
        dbook = nested_codebooks(codebook, db).astype(codebook.dtype)
        if not use_pallas:
            return ref.lut_matmul_bitstream_ref(prefix, dbook, x, bits=db)
        bkw = _tuned_blocks(m, n, p, bits, fmt, blocks, draft_bits=db)
        return lut_matmul_bitstream(prefix, dbook, x, bits=db,
                                    stream_bits=db,
                                    interpret=not _on_tpu(), **bkw)
    if not use_pallas:
        return ref.lut_matmul_nested_ref(packed, codebook, x, bits=bits,
                                         draft_bits=db)
    bkw = _tuned_blocks(m, n, p, bits, fmt, blocks)
    return lut_matmul_nested(packed, codebook, x, bits=bits, draft_bits=db,
                             interpret=not _on_tpu(), **bkw)


def lut_linear(codes_or_packed: jnp.ndarray, codebook: jnp.ndarray,
               x: jnp.ndarray, *, bits: int = 4, packed: bool = False,
               use_pallas: bool = True,
               fmt: Optional[str] = None, blocks=None,
               draft_bits: int = 0) -> jnp.ndarray:
    """Y = W~ @ X for a LUT-quantized layer.

    Args:
      codes_or_packed: (m, n) uint8 codes, (m, ceil(n/2)) nibble-packed,
        (m, ceil(n*bits/8)) true-bitstream packed, or the nested dual
        sub-stream layout for nested formats.
      codebook: (m, 2**bits).
      x: (n, p) activations.
      fmt: optional `WeightFormat` name — when given, the code layout
        (stream width) is read from the registry instead of the `packed`
        flag, so callers route by format tag alone; it also keys the
        autotuned tile-size lookup.
      blocks: optional `tune.BlockPlan` overriding both the tuned cache
        and the kernel defaults.
      draft_bits: > 0 requests the speculative prefix read of a nested
        format (must equal the format's `draft_bits`); ignored — the full
        read — for non-nested formats, whose draft is exact.
    """
    if fmt is not None:
        from repro.core.formats import get_format
        if get_format(fmt).draft_bits:
            return _nested_linear(codes_or_packed, codebook, x, bits=bits,
                                  fmt=fmt, draft_bits=draft_bits,
                                  use_pallas=use_pallas, blocks=blocks)
    sb = _layout(bits, packed, fmt)
    n, p = x.shape
    m = codes_or_packed.shape[0]
    if not use_pallas:
        if sb == 8:
            return ref.lut_matmul_ref(codes_or_packed, codebook, x)
        if sb == 4:
            return ref.lut_matmul_packed_ref(codes_or_packed, codebook, x)
        return ref.lut_matmul_bitstream_ref(codes_or_packed, codebook, x,
                                            bits=sb)
    interpret = not _on_tpu()
    bkw = _tuned_blocks(m, n, p, bits, fmt, blocks)
    if sb == 8:
        return lut_matmul(codes_or_packed, codebook, x, bits=bits,
                          interpret=interpret, **bkw)
    if sb == 4:
        return lut_matmul_packed(codes_or_packed, codebook, x, bits=bits,
                                 interpret=interpret, **bkw)
    return lut_matmul_bitstream(codes_or_packed, codebook, x, bits=bits,
                                stream_bits=sb, interpret=interpret, **bkw)


def split_format_groups(layers: Sequence) -> List[List[int]]:
    """Partition projection indices into fusable sub-groups by format key.

    Mixed-format projection lists (e.g. a policy that packs wq at 4-bit
    but wk/wv at 3-bit) used to fall all the way back to sequential
    launches; instead, indices sharing (fmt, bits, input width, codebook
    dtype) and carrying no sparse side payloads group together — each
    group of >= 2 that passes `groupable_layers` rides one fused launch,
    the rest stay sequential. Returns index groups covering every layer
    exactly once, singletons included.
    """
    from repro.core.formats import get_format
    buckets: dict = {}
    order: List[List[int]] = []
    for i, l in enumerate(layers):
        fmt = getattr(l, "fmt", None)
        key = None
        if fmt is not None and get_format(fmt).groupable \
                and getattr(l, "codes", None) is not None \
                and l.codes.ndim == 2 \
                and l.sparse_val is None and l.full_row_val is None:
            key = (fmt, l.bits, l.shape[1], str(l.codebook.dtype))
        if key is None:
            order.append([i])          # ungroupable: always a singleton
            continue
        if key in buckets:
            buckets[key].append(i)
        else:
            buckets[key] = [i]
            order.append(buckets[key])
    # groups that fail the row-unit / group-count admissibility check are
    # exploded back to singletons (sequential launches)
    out: List[List[int]] = []
    for g in order:
        if len(g) >= 2 and groupable_layers([layers[i] for i in g]):
            out.append(g)
        else:
            out.extend([i] for i in g)
    return out


def groupable_layers(layers: Sequence, min_rows: int = MIN_GROUP_ROWS
                     ) -> bool:
    """True when a list of `QuantizedLinear` can ride one fused launch:
    same groupable format / bits / input width / codebook dtype, no
    sparse or full-row side payloads, and a usable common row unit."""
    from repro.core.formats import get_format
    if len(layers) < 2:
        return False
    fmts = [getattr(l, "fmt", None) for l in layers]
    if fmts[0] is None or any(f != fmts[0] for f in fmts):
        return False
    f = get_format(fmts[0])
    if not f.groupable:
        return False
    l0 = layers[0]
    for l in layers:
        if (l.bits != l0.bits or l.codes.ndim != 2
                or l.shape[1] != l0.shape[1]
                or l.codebook.dtype != l0.codebook.dtype
                or l.sparse_val is not None or l.full_row_val is not None):
            return False
    mu, groups = _group_unit(layers)
    return mu >= min_rows and groups <= MAX_GROUPS


def lut_linear_grouped(layers: Sequence, x: jnp.ndarray, *,
                       use_pallas: bool = True,
                       blocks=None) -> List[jnp.ndarray]:
    """Fused Y_i = W~_i @ X for projections sharing one activation stream.

    layers: `QuantizedLinear`s passing `groupable_layers`; x: (n, p).
    Rows are stacked over an output-group axis in units of
    gcd(m_0, ..., m_{G-1}) so unequal projection widths (GQA Q vs K/V)
    still fuse; X is streamed HBM->VMEM once per tile for the whole group
    instead of once per projection. Returns [(m_i, p), ...].
    """
    from repro.core.formats import get_format
    assert groupable_layers(layers), "layers are not groupable; caller " \
        "must fall back to sequential lut_linear"
    f = get_format(layers[0].fmt)
    bits = layers[0].bits
    n, p = x.shape
    if not use_pallas:
        return [lut_linear(l.codes, l.codebook, x, bits=bits, fmt=l.fmt,
                           use_pallas=False) for l in layers]
    mu, _ = _group_unit(layers)
    cb = layers[0].codes.shape[-1]
    codes = jnp.concatenate(
        [l.codes.reshape(-1, mu, cb) for l in layers], axis=0)
    books = jnp.concatenate(
        [l.codebook.reshape(-1, mu, 1 << bits) for l in layers], axis=0)
    m_total = sum(l.shape[0] for l in layers)
    bkw = _tuned_blocks(m_total, n, p, bits, layers[0].fmt, blocks,
                        groups=codes.shape[0])
    y = lut_matmul_grouped(codes, books, x, bits=bits,
                           stream_bits=f.stream_bits,
                           interpret=not _on_tpu(), **bkw)
    outs = []
    start = 0
    for l in layers:
        gi = l.shape[0] // mu
        outs.append(y[start:start + gi].reshape(l.shape[0], p))
        start += gi
    return outs


def s_step_blocked(w: jnp.ndarray, t: jnp.ndarray, l: jnp.ndarray, *,
                   block_m: int = 128, block_n: int = 128,
                   use_pallas: bool = True):
    """GANQ S-step: Pallas blocked kernel (TPU) or scan oracle fallback."""
    if not use_pallas:
        return ref.backsub_ref(w, t, l)
    codes, wq = backsub(w, t, l, block_m=block_m, block_n=block_n,
                        interpret=not _on_tpu())
    return codes, wq


def vmem_plan(m: int, n: int, p: int, bits: int, block_m: int = 128,
              block_k: int = 512, block_p: int = 128, *,
              fmt: str = "lut4_packed", x_dtype=jnp.bfloat16,
              book_dtype=jnp.float32, out_dtype=None,
              groups: int = 1, draft_bits: int = 0) -> dict:
    """Static VMEM-footprint + HBM-traffic accounting for the LUT-mpGEMM
    kernels — the feasibility filter for `kernels.tune` and the roofline's
    HBM-bytes model (what the kernel actually streams).

    Bytes derive from the real container layout: codes at the format's
    stream width (`code_cols` — e.g. exactly ceil(n*3/8) per row for
    'lut3_packed'), codebooks at `book_dtype` (the quantizer emits fp32,
    not the fp16 the paper assumes), X/Y at their actual dtypes. For
    `groups` > 1 (fused Q/K/V / gate/up launch) `m` is the TOTAL stacked
    row count; X is streamed once per row block of the m/groups-row unit
    instead of once per projection.

    Per grid step resident set: codes tile(s), codebook tile(s), the
    phase-split X tiles, f32 accumulator. HBM traffic: codes read once,
    X read once per row block, Y written once, LUT once.
    """
    from repro.core.formats import get_format
    from repro.core.packing import code_stream_bytes
    f = get_format(fmt)
    levels = 1 << bits
    if draft_bits:
        # nested prefix read: only the leading ceil(n*db/8) bytes of the
        # shared buffer stream, decoded by a 2**db-entry coarse book
        assert draft_bits == f.draft_bits, (draft_bits, f.draft_bits, fmt)
        levels = 1 << draft_bits
    xb = jnp.dtype(x_dtype).itemsize
    bb = jnp.dtype(book_dtype).itemsize
    ob = jnp.dtype(out_dtype).itemsize if out_dtype is not None else xb
    codes_row_bytes = (code_stream_bytes(n, draft_bits) if draft_bits
                       else f.code_cols(n))
    codes_tile_bytes = (code_stream_bytes(block_k, draft_bits) if draft_bits
                        else f.code_cols(block_k))
    vmem = (groups * block_m * codes_tile_bytes    # code byte planes (u8)
            + groups * block_m * levels * bb       # codebook tile(s)
            + block_k * block_p * xb               # X tiles (all phases)
            + groups * block_m * block_p * 4)      # f32 accumulator
    m_unit = m // groups
    n_row_blocks = -(-m_unit // block_m)
    hbm = {
        "codes_bytes": m * codes_row_bytes,
        "lut_bytes": m * levels * bb,
        "x_bytes": n * p * xb * n_row_blocks,   # X re-streamed per row block
        "y_bytes": m * p * ob,
    }
    hbm["total_bytes"] = sum(hbm.values())
    return {"vmem_bytes": vmem, **hbm}
