"""Pallas TPU kernels for GANQ: LUT-mpGEMM serving + S-step quantization."""
from .ops import (groupable_layers, lut_linear, lut_linear_grouped,
                  s_step_blocked, vmem_plan)
from .lut_mpgemm import (lut_matmul, lut_matmul_bitstream,
                         lut_matmul_grouped, lut_matmul_packed)
from .backsub import backsub
from .tune import BlockPlan, autotune, tune_model
