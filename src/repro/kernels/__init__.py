"""Pallas TPU kernels for GANQ: LUT-mpGEMM serving + S-step quantization."""
from .ops import lut_linear, s_step_blocked, vmem_plan
from .lut_mpgemm import lut_matmul, lut_matmul_packed
from .backsub import backsub
