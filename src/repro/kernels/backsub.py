"""GANQ S-step back-substitution Pallas TPU kernel (paper Alg. 1 inner loop).

TPU adaptation of the paper's row-parallel GPU back-substitution:

  * grid = (row_blocks, col_blocks); rows are embarrassingly parallel
    (eq. 2's decomposition), column blocks iterate sequentially in REVERSE
    (j = n-1 .. 0 order demanded by the triangular structure of L);
  * within a column block, a VPU `fori_loop` performs the per-column
    argmin-over-2^N assignment with exact within-block residual feedback;
  * across column blocks, the committed error tile E_blk propagates into all
    earlier columns with ONE MXU matmul per block —
    `R[:, :col0] += E_blk @ L_rows` — converting the scalar feedback chain of
    the GPU formulation into 128x128 systolic tiles. R lives in a VMEM
    scratch accumulator that persists across the sequential grid dimension.

Numerics: f32 throughout (quantization is an offline pass).
Oracle: kernels/ref.py::backsub_ref == core.ganq.s_step.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _backsub_kernel(w_ref, t_ref, l_ref, codes_ref, wq_ref, r_ref, *,
                    bm: int, bn: int, n: int, nk: int, levels: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        r_ref[...] = jnp.zeros_like(r_ref)

    cb = nk - 1 - k            # column block being processed (reverse order)
    col0 = cb * bn             # first global column of this block

    w = w_ref[...].astype(jnp.float32)            # (bm, bn)
    t = t_ref[...].astype(jnp.float32)            # (bm, L)
    lrows = l_ref[...].astype(jnp.float32)        # (bn, n) stripe of L
    local_iota = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)

    def body(i, e_blk):
        jj = bn - 1 - i                            # local column, descending
        gcol = col0 + jj                           # global column
        # L[:, gcol] restricted to this block's rows — within-block feedback
        lcol = pl.load(l_ref, (slice(None), pl.dslice(gcol, 1)))[:, 0]  # (bn,)
        r_within = jnp.sum(e_blk * lcol[None, :].astype(jnp.float32), axis=1)
        r_cross = pl.load(r_ref, (slice(None), pl.dslice(gcol, 1)))[:, 0]
        ljj = pl.load(l_ref, (pl.dslice(jj, 1), pl.dslice(gcol, 1)))[0, 0]
        wcol = pl.load(w_ref, (slice(None), pl.dslice(jj, 1)))[:, 0]
        target = wcol.astype(jnp.float32) + (r_within + r_cross) / ljj
        dist = jnp.abs(target[:, None] - t)        # (bm, L)
        idx = jnp.argmin(dist, axis=1).astype(jnp.int32)
        # decode chosen entry via compare-select (no per-lane gather on TPU)
        wqcol = jnp.zeros((bm,), jnp.float32)
        for s in range(levels):
            wqcol += t[:, s] * (idx == s).astype(jnp.float32)
        ecol = wcol.astype(jnp.float32) - wqcol
        pl.store(codes_ref, (slice(None), pl.dslice(jj, 1)),
                 idx[:, None].astype(codes_ref.dtype))
        pl.store(wq_ref, (slice(None), pl.dslice(jj, 1)),
                 wqcol[:, None].astype(wq_ref.dtype))
        return jnp.where(local_iota == jj, ecol[:, None], e_blk)

    e_blk = jax.lax.fori_loop(0, bn, body, jnp.zeros((bm, bn), jnp.float32))

    # one MXU matmul propagates this block's errors into ALL earlier columns
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (bn, n), 1)
    lmask = jnp.where(col_iota < col0, lrows, 0.0)
    r_ref[...] += jnp.dot(e_blk, lmask, preferred_element_type=jnp.float32)


def _pad_l(l: jnp.ndarray, n_pad: int) -> jnp.ndarray:
    """Extend L to (n_pad, n_pad): identity diagonal, zero coupling for pads."""
    n = l.shape[0]
    if n_pad == n:
        return l
    out = jnp.zeros((n_pad, n_pad), l.dtype)
    out = out.at[:n, :n].set(l)
    pad_idx = jnp.arange(n, n_pad)
    return out.at[pad_idx, pad_idx].set(1.0)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def backsub(w: jnp.ndarray, t: jnp.ndarray, l: jnp.ndarray, *,
            block_m: int = 128, block_n: int = 128,
            interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blocked GANQ S-step. w (m, n), t (m, L), l (n, n) lower-triangular.

    Returns (codes (m, n) int32, wq (m, n) f32) — bit-exact vs the scan
    oracle up to fp reassociation in the residual accumulation.
    """
    m, n = w.shape
    levels = t.shape[1]
    bm, bn = min(block_m, m), min(block_n, n)

    mp = m + (-m) % bm
    np_ = n + (-n) % bn
    wp = jnp.zeros((mp, np_), jnp.float32).at[:m, :n].set(w.astype(jnp.float32))
    tp = jnp.zeros((mp, levels), jnp.float32).at[:m].set(t.astype(jnp.float32))
    lp = _pad_l(l.astype(jnp.float32), np_)
    nm, nk = mp // bm, np_ // bn

    kernel = functools.partial(_backsub_kernel, bm=bm, bn=bn, n=np_, nk=nk,
                               levels=levels)
    codes, wq = pl.pallas_call(
        kernel,
        grid=(nm, nk),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, k: (i, nk - 1 - k)),   # W block
            pl.BlockSpec((bm, levels), lambda i, k: (i, 0)),        # codebook
            pl.BlockSpec((bn, np_), lambda i, k: (nk - 1 - k, 0)),  # L stripe
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, k: (i, nk - 1 - k)),
            pl.BlockSpec((bm, bn), lambda i, k: (i, nk - 1 - k)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.int32),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, np_), jnp.float32)],
        interpret=interpret,
    )(wp, tp, lp)
    return codes[:m, :n], wq[:m, :n]
