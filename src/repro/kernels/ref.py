"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every kernel in this package is validated against these references in
interpret mode across shape/dtype sweeps (tests/test_kernels_*.py).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.ganq import s_step as _s_step_core
from repro.core.packing import (unpack_bits, unpack_bits_nested,
                                unpack_nibbles)


def lut_decode_ref(codes: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """W~[i, j] = T[i, codes[i, j]]; codes (m, n) uint8, T (m, L)."""
    return jnp.take_along_axis(codebook, codes.astype(jnp.int32), axis=1)


def lut_matmul_ref(codes: jnp.ndarray, codebook: jnp.ndarray,
                   x: jnp.ndarray) -> jnp.ndarray:
    """Y = W~ @ X; codes (m, n), T (m, L), x (n, p) -> (m, p).

    Accumulates in f32 (matches the kernel's MXU accumulator) and returns
    x.dtype.
    """
    w = lut_decode_ref(codes, codebook).astype(jnp.float32)
    y = w @ x.astype(jnp.float32)
    return y.astype(x.dtype)


def lut_matmul_packed_ref(packed: jnp.ndarray, codebook: jnp.ndarray,
                          x: jnp.ndarray) -> jnp.ndarray:
    """Same as lut_matmul_ref but codes arrive nibble-packed (m, ceil(n/2))."""
    n = x.shape[0]
    codes = unpack_nibbles(packed, n)
    return lut_matmul_ref(codes, codebook, x)


def lut_matmul_bitstream_ref(packed: jnp.ndarray, codebook: jnp.ndarray,
                             x: jnp.ndarray, *, bits: int) -> jnp.ndarray:
    """Same as lut_matmul_ref but codes arrive as the true
    (m, ceil(n*bits/8)) bitstream (`core.packing.pack_bits` layout)."""
    n = x.shape[0]
    codes = unpack_bits(packed, bits, n)
    return lut_matmul_ref(codes, codebook, x)


def lut_matmul_nested_ref(packed: jnp.ndarray, codebook: jnp.ndarray,
                          x: jnp.ndarray, *, bits: int,
                          draft_bits: int) -> jnp.ndarray:
    """Same as lut_matmul_ref but codes arrive as the nested dual
    sub-stream (`core.packing.pack_bits_nested` layout): the draft_bits
    prefix stream then the (bits - draft_bits) remainder stream."""
    n = x.shape[0]
    codes = unpack_bits_nested(packed, bits, draft_bits, n)
    return lut_matmul_ref(codes, codebook, x)


def backsub_ref(w: jnp.ndarray, t: jnp.ndarray, l: jnp.ndarray):
    """GANQ S-step oracle — defers to the core scan implementation."""
    return _s_step_core(w, t, l)
