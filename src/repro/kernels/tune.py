"""Block-size autotuner for the LUT-mpGEMM kernels.

Serving shapes are static per deployment (m, n fixed by the checkpoint,
p by the slot batch), so tile sizes are a per-shape constant worth
measuring once instead of hardcoding 128/512/128. `autotune` sweeps
(block_m, block_k, block_p) candidates for one `(m, n, p, bits, fmt)`
problem, using `kernels.ops.vmem_plan` as a static feasibility filter
(tiles must fit the VMEM budget) and timed trials of the real kernel on
the current backend for the survivors. Results land in an in-process
dict AND an on-disk JSON cache keyed by shape/backend, so a serving
process picks tuned tiles via `lookup` with zero startup cost once any
prior run (or an explicit `--autotune` pass, cf. launch/serve.py) has
populated the cache.

Off-TPU the kernels run in interpret mode: timings then rank the
emulation, not the hardware — still useful for wiring tests and for the
cache plumbing, which is backend-keyed exactly so TPU and CPU entries
never mix.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ~16 MiB VMEM/core on current TPUs; leave headroom for double buffering
# (the pipeline keeps two copies of every streamed tile in flight).
VMEM_BUDGET_BYTES = 6 * 2 ** 20

_BM = (64, 128, 256, 512)
_BK = (128, 256, 512, 1024, 2048)
_BP = (32, 64, 128, 256)


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """Tile sizes for one LUT-mpGEMM problem."""

    block_m: int
    block_k: int
    block_p: int
    us: float = 0.0              # measured microseconds (0 = untimed default)

    def as_kwargs(self) -> Dict[str, int]:
        return {"block_m": self.block_m, "block_k": self.block_k,
                "block_p": self.block_p}


_MEM_CACHE: Dict[str, BlockPlan] = {}
_DISK_LOADED: set = set()


def cache_path() -> Path:
    """On-disk cache location; override with REPRO_TUNE_CACHE."""
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return Path(env).expanduser()
    return Path.home() / ".cache" / "repro" / "lut_blocks.json"


def plan_key(m: int, n: int, p: int, bits: int, fmt: str,
             backend: Optional[str] = None, groups: int = 1,
             draft_bits: int = 0) -> str:
    backend = backend or jax.default_backend()
    gtag = f"|g{groups}" if groups != 1 else ""
    # the nested draft (prefix) read streams fewer bytes per tile than the
    # full-width read of the same layer, so it tunes under its own key
    dtag = f"|d{draft_bits}" if draft_bits else ""
    return f"{backend}|{fmt}|b{bits}|{m}x{n}x{p}{gtag}{dtag}"


def _load_disk(path: Path) -> None:
    if str(path) in _DISK_LOADED:
        return
    _DISK_LOADED.add(str(path))
    try:
        raw = json.loads(path.read_text())
    except (OSError, ValueError):
        return
    for k, v in raw.items():
        if k not in _MEM_CACHE:
            _MEM_CACHE[k] = BlockPlan(v["block_m"], v["block_k"],
                                      v["block_p"], v.get("us", 0.0))


def _save_disk(path: Path) -> None:
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        payload = {k: dataclasses.asdict(v) for k, v in _MEM_CACHE.items()}
        path.write_text(json.dumps(payload, indent=1, sort_keys=True))
    except OSError:
        pass                      # cache is an optimization, never a failure


def clear_cache() -> None:
    """Drop the in-process cache (tests; disk entries reload on demand)."""
    _MEM_CACHE.clear()
    _DISK_LOADED.clear()


def lookup(m: int, n: int, p: int, bits: int, fmt: str,
           groups: int = 1, draft_bits: int = 0) -> Optional[BlockPlan]:
    """Cached plan for a problem, or None (callers keep their defaults).
    Checks the in-process dict first, then lazily loads the disk cache."""
    key = plan_key(m, n, p, bits, fmt, groups=groups, draft_bits=draft_bits)
    if key not in _MEM_CACHE:
        _load_disk(cache_path())
    return _MEM_CACHE.get(key)


def candidate_plans(m: int, n: int, p: int, bits: int, fmt: str,
                    groups: int = 1,
                    vmem_budget: int = VMEM_BUDGET_BYTES,
                    draft_bits: int = 0) -> List[BlockPlan]:
    """Deduplicated (block_m, block_k, block_p) candidates that pass the
    static `vmem_plan` feasibility filter for this problem."""
    from .ops import vmem_plan               # late: ops imports this module
    seen = set()
    out = []
    for bm in _BM:
        for bk in _BK:
            for bp in _BP:
                cand = (min(bm, m), min(bk, n), min(bp, p))
                if cand in seen:
                    continue
                seen.add(cand)
                plan = vmem_plan(m, n, p, bits, *cand, fmt=fmt,
                                 groups=groups, draft_bits=draft_bits)
                if plan["vmem_bytes"] <= vmem_budget:
                    out.append(BlockPlan(*cand))
    return out


def _synthetic_problem(m: int, n: int, p: int, bits: int, fmt: str):
    """Random container + activations in the format's real layout."""
    from repro.core.formats import get_format
    f = get_format(fmt)
    rng = np.random.default_rng(0)
    cols = f.code_cols(n) if f.packed else n
    codes = jnp.asarray(rng.integers(0, 256 if f.packed else (1 << bits),
                                     size=(m, cols)).astype(np.uint8))
    book = jnp.asarray(rng.normal(size=(m, 1 << bits)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    return codes, book, x


def _time_plan(run, reps: int) -> float:
    assert reps >= 1, reps
    jax.block_until_ready(run())              # compile / warm, drained
    t0 = time.perf_counter()
    for _ in range(reps):
        out = run()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def autotune(m: int, n: int, p: int, bits: int, fmt: str, *,
             reps: int = 3, max_candidates: int = 8,
             save: bool = True, draft_bits: int = 0) -> BlockPlan:
    """Measure feasible tile candidates for one problem and cache the
    winner. Returns the cached plan immediately when one exists."""
    cached = lookup(m, n, p, bits, fmt, draft_bits=draft_bits)
    if cached is not None:
        return cached
    from .ops import lut_linear
    codes, book, x = _synthetic_problem(m, n, p, bits, fmt)
    cands = candidate_plans(m, n, p, bits, fmt, draft_bits=draft_bits)
    if not cands:                             # nothing fits: smallest tiles
        cands = [BlockPlan(min(64, m), min(128, n), min(32, p))]
    # prefer large-tile candidates first, keep the sweep bounded
    cands = sorted(cands, key=lambda c: -(c.block_m * c.block_k
                                          * c.block_p))[:max_candidates]
    best = None
    for cand in cands:
        us = _time_plan(
            lambda c=cand: lut_linear(codes, book, x, bits=bits, fmt=fmt,
                                      blocks=c, draft_bits=draft_bits), reps)
        if best is None or us < best.us:
            best = dataclasses.replace(cand, us=us)
    key = plan_key(m, n, p, bits, fmt, draft_bits=draft_bits)
    _MEM_CACHE[key] = best
    if save:
        _save_disk(cache_path())
    return best


def autotune_grouped(layers, p: int, *, reps: int = 3,
                     max_candidates: int = 8,
                     save: bool = True) -> Optional[BlockPlan]:
    """Tune the fused multi-projection launch for a sibling set (Q/K/V,
    gate/up) that passes `groupable_layers`. Plans are cached under the
    group-tagged key the grouped serving path looks up — distinct from
    the groups=1 keys, since the fused kernel's VMEM scales with the
    group count. Returns None for non-groupable input."""
    from .ops import _group_unit, groupable_layers, lut_linear_grouped
    if not groupable_layers(layers):
        return None
    _, groups = _group_unit(layers)
    m_total = sum(l.shape[0] for l in layers)
    n = layers[0].shape[1]
    bits, fmt = layers[0].bits, layers[0].fmt
    key = plan_key(m_total, n, p, bits, fmt, groups=groups)
    if key not in _MEM_CACHE:
        _load_disk(cache_path())
    if key in _MEM_CACHE:
        return _MEM_CACHE[key]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(n, p)).astype(np.float32))
    cands = candidate_plans(m_total, n, p, bits, fmt, groups=groups)
    if not cands:
        cands = [BlockPlan(min(64, m_total), min(128, n), min(32, p))]
    cands = sorted(cands, key=lambda c: -(c.block_m * c.block_k
                                          * c.block_p))[:max_candidates]
    best = None
    for cand in cands:
        us = _time_plan(
            lambda c=cand: lut_linear_grouped(layers, x, blocks=c), reps)
        if best is None or us < best.us:
            best = dataclasses.replace(cand, us=us)
    _MEM_CACHE[key] = best
    if save:
        _save_disk(cache_path())
    return best


# sibling projections the models fuse (attention.project_qkv, mlp_apply)
_GROUP_SIBLINGS = (("wq", "wk", "wv"), ("w_gate", "w_up"))


def _unit_view(leaf):
    """2-D view of a possibly stacked-unit (U, m, nc) container — the
    shape the per-unit apply actually serves."""
    if leaf.codes.ndim == 2:
        return leaf
    return dataclasses.replace(
        leaf, codes=leaf.codes[0], codebook=leaf.codebook[0],
        sparse_idx=None if leaf.sparse_idx is None else leaf.sparse_idx[0],
        sparse_val=None if leaf.sparse_val is None else leaf.sparse_val[0],
        full_row_idx=None, full_row_val=None, bias=None)


def tune_model(qparams, p: int, *, reps: int = 3,
               save: bool = True) -> Dict[str, BlockPlan]:
    """Autotune every distinct quantized-linear problem in a param tree
    for decode width `p` (the slot batch) — per-layer launches AND the
    fused Q/K/V / gate/up sibling groups the grouped serving path keys
    on. Returns {key: plan}. The disk cache is written once at the end."""
    from repro.core.formats import get_format
    from repro.core.types import QuantizedLinear
    problems: Dict[Tuple, None] = {}
    group_problems: Dict[str, list] = {}

    def visit(node):
        if isinstance(node, dict):
            for sibs in _GROUP_SIBLINGS:
                if all(isinstance(node.get(k), QuantizedLinear)
                       for k in sibs):
                    views = [_unit_view(node[k]) for k in sibs]
                    gkey = "|".join(f"{v.fmt}:{v.bits}:{v.shape}"
                                    for v in views)
                    group_problems.setdefault(gkey, views)
            for v in node.values():
                visit(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                visit(v)
        elif isinstance(node, QuantizedLinear):
            fmt = get_format(node.fmt)
            if fmt.stream_bits is not None:
                # stacked-unit leaves are (U, m, nc); apply sees 2-D slices
                mm = node.codes.shape[-2]
                nn = node.n_cols if fmt.packed else node.codes.shape[-1]
                problems[(mm, nn, p, node.bits, node.fmt, 0)] = None
                if fmt.draft_bits:
                    # nested formats serve a second, prefix-width read
                    problems[(mm, nn, p, node.bits, node.fmt,
                              fmt.draft_bits)] = None
    visit(qparams)
    out = {}
    for (mm, nn, pp, bits, fmt, db) in problems:
        plan = autotune(mm, nn, pp, bits, fmt, reps=reps, save=False,
                        draft_bits=db)
        out[plan_key(mm, nn, pp, bits, fmt, draft_bits=db)] = plan
    for views in group_problems.values():
        plan = autotune_grouped(views, p, reps=reps, save=False)
        if plan is not None:
            from .ops import _group_unit
            _, groups = _group_unit(views)
            m_total = sum(v.shape[0] for v in views)
            out[plan_key(m_total, views[0].shape[1], p, views[0].bits,
                         views[0].fmt, groups=groups)] = plan
    if save:
        _save_disk(cache_path())
    return out
